"""Prefill classing + tenant SLO classes (DESIGN.md §19) and the two
scheduling fixes the feature is anchored on:

  * **incremental-deadline fix** — ``Coordinator.laxity`` (and the
    preemptive queue order) used to price EVERY round against
    ``ttft_thres``; an urgent increment with a tight TTIT deadline ordered
    behind any long first prompt that merely arrived earlier.  Deadlines
    now resolve per task class (TTFT for round 0, TTIT for round > 0,
    tenant overrides on top).
  * **stale-index routing fix** — ``route_prefill`` / ``always_remote``
    used to return the candidate's *enumerate position* in
    ``RouteDecision.worker_idx`` while cache plans (and every other
    consumer) key workers by stable id; a §18 hot swap reordering the
    prefill list between pricing and dispatch crossed wires.  Decisions
    now carry the stable id end to end and dispatch resolves through
    ``ServingRuntime.worker_by_id``.

Plus the trace-layer satellites: the cap-censored geometric round sampler
(GAIA's Table-1 mean no longer biased low by the 64-round cap), guarded
``trace_stats`` on empty lists, and the blended multi-tenant
``make_mixed_trace`` with deterministic per-tenant labels.
"""
import dataclasses
import random
from collections import Counter

import pytest

from repro.configs import get_config
from repro.core import (
    Deployment,
    PerfModel,
    RoutingConfig,
    SimConfig,
    Simulation,
    SLOSpec,
    WorkerGroup,
    route_prefill,
    simulate_deployment,
)
from repro.core.planner import classed_variants
from repro.core.routing import always_remote, class_eligible
from repro.core.simulator import SimWorker, WindowStat
from repro.core.types import (
    FIRST_PROMPT,
    INCREMENTAL,
    ClassThresholds,
    PrefillTask,
    RoundSpec,
    Session,
)
from repro.runtime import Coordinator
from repro.runtime.coordinator import StealingConfig
from repro.workloads import DEFAULT_TENANTS, TRACES, make_mixed_trace, make_trace
from repro.workloads.traces import ROUNDS_CAP, _geom_p, trace_stats


def _perf():
    return PerfModel(get_config("qwen3-32b"))


def _task(sid=0, round_idx=0, l_hist=0, l_incr=512, arrival=0.0,
          tenant="default"):
    return PrefillTask(session_id=sid, round_idx=round_idx, l_hist=l_hist,
                       l_incr=l_incr, enqueue_time=arrival,
                       arrival_time=arrival, tenant=tenant)


def _worker(kind, idx=0, tp=4, ttft=0.0, itl=0.0, queue=(), pclass=""):
    w = SimWorker(idx, tp, kind)
    w.windowed_ttft = ttft
    w.windowed_itl = itl
    w.prefill_queue = list(queue)
    w.pclass = pclass
    return w


# ---------------------------------------------------------------------------
# task / SLO classing surface
# ---------------------------------------------------------------------------

def test_prefill_class_derived_from_round():
    assert _task(round_idx=0).prefill_class == FIRST_PROMPT
    assert _task(round_idx=3).prefill_class == INCREMENTAL
    # chunks of round 0 (incr_offset > 0) are still the first prompt
    chunk = PrefillTask(session_id=0, round_idx=0, l_hist=256, l_incr=256,
                        enqueue_time=0.0, arrival_time=0.0, incr_offset=256)
    assert chunk.prefill_class == FIRST_PROMPT


def test_class_eligibility_gate():
    first = _worker("prefill", idx=0, pclass=FIRST_PROMPT)
    incr = _worker("prefill", idx=1, pclass=INCREMENTAL)
    shared = _worker("prefill", idx=2)
    t0, t3 = _task(round_idx=0), _task(round_idx=3)
    assert class_eligible(first, t0) and not class_eligible(first, t3)
    assert class_eligible(incr, t3) and not class_eligible(incr, t0)
    assert class_eligible(shared, t0) and class_eligible(shared, t3)


def test_slo_round_deadline_fallback_chain():
    slo = SLOSpec(ttft_thres=2.0, itl_thres=0.1, ttit_thres=0.5,
                  tenants={"interactive": ClassThresholds(ttit=0.3),
                           "gold": ClassThresholds(ttft=1.0, itl=0.05)})
    assert slo.round_deadline(0, "default") == 2.0
    assert slo.round_deadline(0, "gold") == 1.0
    assert slo.round_deadline(3, "default") == 0.5       # spec ttit
    assert slo.round_deadline(3, "interactive") == 0.3   # tenant ttit
    assert slo.round_deadline(3, "gold") == 0.5          # spec ttit wins
    assert slo.itl_for("gold") == 0.05 and slo.itl_for("default") == 0.1
    # no spec ttit: a tenant's ttft is its increments' fallback deadline
    t_only = SLOSpec(ttft_thres=2.0, itl_thres=0.1,
                     tenants={"gold": ClassThresholds(ttft=1.0)})
    assert t_only.round_deadline(3, "gold") == 1.0
    # no ttit anywhere -> class-blind: every round against ttft
    blind = SLOSpec(ttft_thres=2.0, itl_thres=0.1)
    assert blind.round_deadline(5, "default") == 2.0


def test_slo_satisfied_judges_increments_by_ttit():
    slo = SLOSpec(ttft_thres=2.0, itl_thres=10.0, ttit_thres=0.5)
    s = Session(session_id=0, arrival_time=0.0,
                rounds=[RoundSpec(64, 4, 0.0), RoundSpec(64, 4, 0.0)])
    s.ttfts = [1.5, 1.5]          # round 1 misses its 0.5s TTIT
    s.itls = [0.01] * 8
    assert not slo.satisfied(s)
    s.ttfts = [1.5, 0.4]
    assert slo.satisfied(s)


# ---------------------------------------------------------------------------
# satellite 1: incremental rounds get their own deadline in laxity/ordering
# ---------------------------------------------------------------------------

def test_urgent_increment_outranks_long_first_prompt():
    """Pre-fix pathology: under overload, a round-3 increment with a 0.5s
    TTIT deadline was priced against the 10s TTFT threshold and ordered
    BEHIND a huge round-0 prompt that arrived earlier.  With class
    deadlines the increment's laxity is far smaller and it runs first."""
    perf = _perf()
    routing = RoutingConfig(ttft_thres=10.0, itl_thres=0.1, ttit_thres=0.5)
    co = Coordinator(perf=perf, routing=routing, stealing=StealingConfig())
    w = _worker("prefill", idx=0)
    first = _task(sid=0, round_idx=0, l_incr=8192, arrival=0.0)
    incr = _task(sid=1, round_idx=3, l_hist=2048, l_incr=128, arrival=1.0)
    now = 1.0
    # deadline = arrival + class threshold: 10.0 vs 1.0 + 0.5
    assert co.laxity(incr, w, now) < co.laxity(first, w, now)
    w.prefill_queue = [first, incr]
    co.order_queue(w, now)
    assert w.prefill_queue[0] is incr, (
        "urgent increment must preempt the long first prompt at the head")
    # class-blind config (no ttit): both priced against ttft -> the earlier
    # arrival keeps the head, i.e. the fix only engages with class deadlines
    blind = Coordinator(perf=perf, stealing=StealingConfig(),
                        routing=RoutingConfig(ttft_thres=10.0, itl_thres=0.1))
    w2 = _worker("prefill", idx=0, queue=[first, incr])
    blind.order_queue(w2, now)
    assert w2.prefill_queue[0] is first


def test_tenant_override_tightens_increment_deadline():
    routing = RoutingConfig(ttft_thres=10.0, itl_thres=0.1, ttit_thres=2.0,
                            tenants={"interactive": ClassThresholds(ttit=0.2)})
    hot = _task(sid=1, round_idx=2, l_incr=128, tenant="interactive")
    warm = _task(sid=2, round_idx=2, l_incr=128, tenant="batch")
    assert routing.deadline_for(hot) == 0.2
    assert routing.deadline_for(warm) == 2.0
    assert routing.deadline_for(_task(round_idx=0, tenant="interactive")) == 10.0


# ---------------------------------------------------------------------------
# satellite 2: RouteDecision carries the stable id, not a list position
# ---------------------------------------------------------------------------

def test_route_decision_is_stable_id_under_list_reorder():
    """A §18 hot swap may reorder/extend ``prefill_workers`` between
    pricing and dispatch: the decision must name the SAME worker under any
    list order — i.e. by its stable id, never its enumerate position."""
    cfg = RoutingConfig(ttft_thres=2.0, itl_thres=0.1)
    perf = _perf()
    d = _worker("decode", idx=0, itl=0.5)
    idle = _worker("prefill", idx=9, ttft=0.1)
    busy = _worker("prefill", idx=4, ttft=100.0,
                   queue=[_task(l_incr=8000) for _ in range(20)])
    for order in ([busy, idle], [idle, busy]):
        dec = route_prefill(_task(), d, order, perf, cfg, random.Random(0))
        assert dec.kind == "remote" and dec.worker_idx == idle.idx
        dec2 = always_remote(_task(), d, order, perf, cfg, random.Random(0))
        assert dec2.worker_idx == idle.idx
    # cost path (nobody has slack, local expensive): the cheaper worker,
    # named by stable id under either list order
    busy_d = _worker("decode", idx=0, itl=0.5,
                     queue=[_task(l_incr=4096) for _ in range(4)])
    slow = _worker("prefill", idx=7, ttft=5.0)
    slow.speed = 0.25
    fast = _worker("prefill", idx=3, ttft=5.0)
    for order in ([slow, fast], [fast, slow]):
        dec = route_prefill(_task(l_incr=4096), busy_d, order, perf, cfg,
                            random.Random(0))
        assert dec.kind == "remote" and dec.worker_idx == fast.idx


def test_dispatch_resolves_stable_id_across_hot_swap_reorder():
    """End to end through ``ServingRuntime``: reorder the live prefill list
    the way an autoscaler swap does (retire-in-place + append means ids
    stop matching positions) and the trace still drains with every remote
    chunk landing on the worker the decision named."""
    ss = make_trace("toolbench", num_sessions=30, arrival_rate=2.0, seed=11)
    dep = Deployment((WorkerGroup(4, 3),), (WorkerGroup(4, 2),))
    slo = SLOSpec(ttft_thres=3.0, itl_thres=0.15)
    sim = Simulation(_perf(), dep, ss, slo, SimConfig(scheduler="dynamo"))
    sim.coordinator.record_decisions = True
    # ids [2, 0, 1]: every position now disagrees with its stable id
    sim.runtime.prefill_workers[:] = (sim.runtime.prefill_workers[2:]
                                      + sim.runtime.prefill_workers[:2])
    r = sim.run()
    assert all(s.finish_time is not None for s in r.sessions)
    ids = {w.idx for w in sim.prefill_workers}
    remotes = [w_idx for (_s, _r, _o, kind, w_idx)
               in sim.coordinator.decision_log if kind == "remote"]
    assert remotes and all(w_idx in ids for w_idx in remotes)
    # the id a decision names is the worker that did the work: every
    # prefill worker saw tasks (dynamo spreads by cost across all three)
    assert all(w.tasks_done > 0 for w in sim.prefill_workers)


# ---------------------------------------------------------------------------
# satellite 3: cap-censored geometric rounds + guarded trace stats
# ---------------------------------------------------------------------------

def test_geom_p_inverts_censored_mean():
    # E[min(G_p, cap)] = (1-(1-p)^cap)/p must equal the requested mean
    for mean in (2.0, 3.96, 11.32, 40.0):
        p = _geom_p(mean)
        m = (1.0 - (1.0 - p) ** ROUNDS_CAP) / p
        assert abs(m - mean) < 1e-6, (mean, m)
    assert _geom_p(1.0) == 1.0
    assert _geom_p(0.5) == 1.0
    with pytest.raises(ValueError):
        _geom_p(float(ROUNDS_CAP))


def test_gaia_round_mean_is_cap_corrected():
    """The old p=1/mean sampler under the 64-round cap biased GAIA's
    sample mean to ~11.0 against the 11.32 Table-1 target; the censored
    inversion recovers it within sampling noise."""
    ss = make_trace("gaia", num_sessions=20000, arrival_rate=10.0, seed=1)
    mean = sum(s.num_rounds for s in ss) / len(ss)
    assert max(s.num_rounds for s in ss) <= ROUNDS_CAP
    assert abs(mean - TRACES["gaia"].mean_rounds) < 0.15, mean


def test_trace_stats_empty_is_zero_not_crash():
    st = trace_stats([])
    assert st == {"sessions": 0, "avg_rounds": 0.0,
                  "avg_prefill_len": 0.0, "avg_decode_len": 0.0}


# ---------------------------------------------------------------------------
# satellite 4: blended multi-tenant trace regression
# ---------------------------------------------------------------------------

def test_mixed_trace_blends_components_concurrently():
    ss = make_mixed_trace(num_sessions=2000, arrival_rate=4.0, seed=3)
    comps = Counter(s.trace for s in ss)
    assert set(comps) == {"toolbench", "gaia", "hotpotqa", "dureader"}
    # one arrival stream, interleaved — not four back-to-back blocks
    times = [s.arrival_time for s in ss]
    assert times == sorted(times)
    first_half = Counter(s.trace for s in ss[:1000])
    assert set(first_half) == set(comps)
    # per-component bodies still reproduce their Table-1 means
    for name, spec in TRACES.items():
        st = trace_stats([s for s in ss if s.trace == name])
        assert st["sessions"] > 0
        assert abs(st["avg_rounds"] - spec.mean_rounds) \
            < 0.15 * spec.mean_rounds
        assert abs(st["avg_prefill_len"] - spec.mean_prefill) \
            < 0.2 * spec.mean_prefill
    # tenants follow the default map; labels + bodies deterministic per seed
    assert all(s.tenant == DEFAULT_TENANTS[s.trace] for s in ss)
    again = make_mixed_trace(num_sessions=2000, arrival_rate=4.0, seed=3)
    assert [(s.trace, s.tenant, s.num_rounds) for s in again] \
        == [(s.trace, s.tenant, s.num_rounds) for s in ss]


def test_mixed_trace_weights_and_overrides():
    ss = make_mixed_trace(("toolbench", "gaia"), num_sessions=300,
                          arrival_rate=4.0, seed=5, weights=(1.0, 0.0),
                          tenants={"toolbench": "gold"})
    assert {s.trace for s in ss} == {"toolbench"}
    assert {s.tenant for s in ss} == {"gold"}
    with pytest.raises(ValueError):
        make_mixed_trace((), num_sessions=10)
    with pytest.raises(ValueError):
        make_mixed_trace(("toolbench",), num_sessions=10, weights=(1.0, 2.0))


# ---------------------------------------------------------------------------
# per-class attainment on both result types; classed planner variants
# ---------------------------------------------------------------------------

CLASSED_SLO = SLOSpec(
    ttft_thres=3.0, itl_thres=0.15, ttit_thres=1.5,
    tenants={"interactive": ClassThresholds(ttit=1.0)})


def test_sim_result_reports_per_class_attainment():
    ss = make_mixed_trace(("toolbench", "hotpotqa", "gaia"), num_sessions=60,
                          arrival_rate=1.0, seed=7)
    dep = Deployment((WorkerGroup(4, 2),), (WorkerGroup(4, 2),))
    r = simulate_deployment(_perf(), dep, ss, CLASSED_SLO, scheduler="ampd")
    assert set(r.class_attainment) == {s.tenant for s in ss}
    assert all(0.0 <= v <= 1.0 for v in r.class_attainment.values())
    # per-class numbers decompose the scalar attainment exactly
    by = Counter(s.tenant for s in r.sessions)
    recomposed = sum(r.class_attainment[t] * n for t, n in by.items()) \
        / sum(by.values())
    assert abs(recomposed - r.slo_attainment) < 1e-9


def test_classed_deployment_dedicates_pools():
    """A classed Deployment (planner pclass groups) must keep first
    prompts off the incremental pool and vice versa in a full sim run."""
    ss = make_mixed_trace(("toolbench", "hotpotqa"), num_sessions=40,
                          arrival_rate=1.5, seed=9)
    dep = Deployment((WorkerGroup(4, 1, pclass=FIRST_PROMPT),
                      WorkerGroup(4, 1, pclass=INCREMENTAL)),
                     (WorkerGroup(4, 2),))
    sim = Simulation(_perf(), dep, ss, CLASSED_SLO,
                     SimConfig(scheduler="dynamo"))
    sim.coordinator.record_decisions = True
    r = sim.run()
    assert all(s.finish_time is not None for s in r.sessions)
    assert [w.pclass for w in sim.prefill_workers] \
        == [FIRST_PROMPT, INCREMENTAL]
    for sid, round_idx, _off, kind, w_idx in sim.coordinator.decision_log:
        if kind == "remote":
            assert w_idx == (0 if round_idx == 0 else 1), (
                f"round {round_idx} leaked onto worker {w_idx}")


def test_classed_variants_split_prefill_pool():
    base = Deployment((WorkerGroup(4, 3),), (WorkerGroup(4, 2),))
    vs = classed_variants(base)
    assert len(vs) == 2                      # nf in {1, 2}
    for v in vs:
        assert sum(g.count for g in v.prefill) == 3
        assert {g.pclass for g in v.prefill} == {FIRST_PROMPT, INCREMENTAL}
        assert v.decode == base.decode
    # too small to split
    assert classed_variants(
        Deployment((WorkerGroup(4, 1),), (WorkerGroup(4, 1),))) == []


def test_live_result_has_class_attainment_field():
    from repro.serving.cluster import LiveResult
    f = {x.name for x in dataclasses.fields(LiveResult)}
    assert "class_attainment" in f


def test_live_cluster_reports_per_class_attainment():
    """End to end on the measured backend: classed prefill pools via
    SchedPolicy.prefill_classes + tenant labels from make_live_sessions
    populate LiveResult.class_attainment."""
    from repro.serving import (
        ClusterSpec, LiveCluster, SchedPolicy, make_live_sessions)
    cfg = get_config("qwen2.5-14b").reduced()
    cl = LiveCluster(
        cfg, spec=ClusterSpec(n_prefill=2, n_decode=1, max_slots=4,
                              max_len=128),
        policy=SchedPolicy(scheduler="dynamo",
                           prefill_classes=(FIRST_PROMPT, INCREMENTAL)),
        slo=CLASSED_SLO, seed=0, profile=False)
    assert [w.pclass for w in cl.prefill_workers] \
        == [FIRST_PROMPT, INCREMENTAL]
    sessions = make_live_sessions(cfg, num_sessions=4, rounds=2,
                                  prefill_len=16, decode_len=4,
                                  tenants=["interactive", "batch"])
    r = cl.run_trace(sessions)
    assert all(s.finish_time is not None for s in sessions)
    assert set(r.class_attainment) == {"interactive", "batch"}
    assert all(0.0 <= v <= 1.0 for v in r.class_attainment.values())
