"""Property suite for the global KV layer (DESIGN.md §17).

Randomized model-based testing of the content-addressed page pool: a
shadow refcount ledger replays every protocol-point mutation the
:class:`~repro.runtime.kv_pool.PoolManager` sees and the invariants the
design promises are asserted after every step —

  * refcount conservation: the pool's per-session ledgers match the
    shadow exactly (``refcount == sum(refs.values())`` via ``audit``);
  * no page is ever freed while any session still references it;
  * dedup soundness: equal chain hash ⇒ one physical page (a group's
    shared head maps to identical chain prefixes, divergent tails);
  * the LRU never evicts (or demotes) a pinned / in-flight page;
  * spill → promote round-trips are byte-identical in the material
    store, and measured into ``(bytes, seconds)`` samples.

Runs under hypothesis when available; the container does not ship it, so
the default path is a seeded fallback driving the same state machine
through ``pytest.mark.parametrize`` — deterministic, replayable seeds.

The live half pins the §17 recovery fix: after a decode-worker death the
replay routes through a CachePlan, so a rebind target that already holds
the (cross-session deduped) prefix re-reads only the miss suffix instead
of the full history.  Modeled and live twins inject the same failure and
must both attach the same 16-token resident prefix.
"""
import random

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    Deployment,
    PerfModel,
    SimConfig,
    Simulation,
    SLOSpec,
    WorkerGroup,
)
from repro.core.routing import RoutingConfig
from repro.core.types import RoundSpec, Session
from repro.runtime.kv_pool import (
    TIER_HBM,
    TIER_HOST,
    CachePlan,
    KVPoolConfig,
    Page,
    PoolManager,
    miss_plan,
)

try:                                    # not in the container image: the
    from hypothesis import given, settings      # seeded fallback drives the
    from hypothesis import strategies as st     # same machine deterministically
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_FALLBACK_SEEDS = 20


def seeded_property(fn):
    """``@given(seed=...)`` under hypothesis, parametrized seeds without."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=40, deadline=None)(
            given(seed=st.integers(min_value=0, max_value=2 ** 32 - 1))(fn))
    return pytest.mark.parametrize("seed", range(N_FALLBACK_SEEDS))(fn)


# ---------------------------------------------------------------------------
# randomized state machine over PoolManager, with a shadow refcount ledger
# ---------------------------------------------------------------------------

WORKERS = (("prefill", 0), ("decode", 0), ("decode", 1))
N_SESSIONS = 4
GROUP_OF = {0: 0, 1: 0, 2: 1, 3: 1}     # two prefix-sharing groups


class PoolMachine:
    """Drives a PoolManager through random protocol-point mutations while a
    shadow ledger independently replays the reference counting."""

    def __init__(self, seed: int):
        self.rng = random.Random(seed)
        pt = self.rng.choice((2, 4))
        self.cfg = KVPoolConfig(page_tokens=pt,
                                hbm_pages=self.rng.randint(2, 5),
                                host_pages=self.rng.randint(2, 6))
        self.pm = PoolManager(self.cfg)
        self.shared_head = pt * self.rng.randint(1, 3)
        #: (worker, key) -> {session_id: n} — the independent refcount replay
        self.shadow = {}
        #: in-flight chunks still holding pins: (worker, sid, plan)
        self.inflight = []

    # -- symbol model: shared group head, session-unique tail --------------
    def _symbol(self, sid: int, j: int):
        if j < self.shared_head:
            return ("g", GROUP_OF[sid], j)
        return ("s", sid, j)

    def _extend(self, sid: int, upto: int) -> None:
        self.pm.extend_stream(
            sid, upto,
            lambda lo, n: [self._symbol(sid, j) for j in range(lo, lo + n)])

    def _shadow_ref(self, worker, key, sid) -> None:
        refs = self.shadow.setdefault((worker, key), {})
        refs[sid] = refs.get(sid, 0) + 1

    # -- ops ---------------------------------------------------------------
    def op_extend(self) -> None:
        sid = self.rng.randrange(N_SESSIONS)
        cur = len(self.pm.streams.get(sid, []))
        self._extend(sid, cur + self.rng.randint(1, 3 * self.cfg.page_tokens))

    def op_insert(self) -> None:
        sid = self.rng.randrange(N_SESSIONS)
        stream = self.pm.streams.get(sid, [])
        if not stream:
            return
        worker = self.rng.choice(WORKERS)
        lo = self.rng.randrange(len(stream))
        hi = self.rng.randint(lo, len(stream))
        chain, pt = self.pm.chains.get(sid, []), self.cfg.page_tokens
        for k in range((lo + pt - 1) // pt, min(hi // pt, len(chain))):
            self._shadow_ref(worker, chain[k], sid)
        self.pm.insert_range(worker, sid, lo, hi, None)

    def op_plan_exec(self) -> None:
        sid = self.rng.randrange(N_SESSIONS)
        stream = self.pm.streams.get(sid, [])
        if not stream:
            return
        worker = self.rng.choice(WORKERS)
        l_hist = self.rng.randint(0, len(stream))
        if self.rng.random() < 0.25:
            plan = self.pm.recovery_plan(worker, sid, l_hist)
            assert not plan.pages or plan.prefix_tokens < l_hist
        else:
            plan = self.pm.plan_for(worker, sid, l_hist)
        assert plan.total_tokens == max(l_hist, 0)
        for key in plan.pages:
            self._shadow_ref(worker, key, sid)
        self.pm.execute_plan(worker, sid, plan, None)
        if plan.pages:
            self.inflight.append((worker, sid, plan))

    def op_finish(self) -> None:
        if not self.inflight:
            return
        worker, _sid, plan = self.inflight.pop(
            self.rng.randrange(len(self.inflight)))
        self.pm.finish_chunk(worker, plan)

    def op_release(self) -> None:
        sid = self.rng.randrange(N_SESSIONS)
        self.pm.release_session(sid)
        for refs in self.shadow.values():
            refs.pop(sid, None)

    def op_drop(self) -> None:
        worker = self.rng.choice(WORKERS)
        self.pm.drop_worker(worker)
        self.shadow = {wk: r for wk, r in self.shadow.items()
                       if wk[0] != worker}
        self.inflight = [e for e in self.inflight if e[0] != worker]

    # -- invariants --------------------------------------------------------
    def check(self) -> None:
        self.pm.audit()                 # refcount + tier-count conservation
        pinned = {}                     # (worker, key) -> expected pin count
        for worker, _sid, plan in self.inflight:
            for key in plan.pages:
                pinned[(worker, key)] = pinned.get((worker, key), 0) + 1
        for wk, pool in self.pm.pools.items():
            for key, p in pool.pages.items():
                assert p.tokens == self.cfg.page_tokens
                assert p.lo % self.cfg.page_tokens == 0
                # refcount conservation against the independent shadow
                exp = {s: n for s, n in
                       self.shadow.get((wk, key), {}).items() if n > 0}
                assert p.refs == exp, (wk, key, p.refs, exp)
                # an in-flight (pinned) page is never demoted out of HBM
                assert p.pins == pinned.get((wk, key), 0)
                if p.pins > 0:
                    assert p.tier == TIER_HBM
        # a pinned page is never EVICTED either
        for (worker, key), n in pinned.items():
            assert key in self.pm.pools[worker].pages
        # no page freed while referenced: a shadow entry whose page is gone
        # must have been unreferenced at eviction time
        for (wk, key) in list(self.shadow):
            pool = self.pm.pools.get(wk)
            if pool is None or key not in pool.pages:
                live = {s: n for s, n in self.shadow[(wk, key)].items()
                        if n > 0}
                assert not live, f"{key} freed while referenced: {live}"
                del self.shadow[(wk, key)]

    def check_dedup(self) -> None:
        """Equal content ⇒ equal chain prefix; divergent content ⇒
        divergent keys from the first differing page onward."""
        pt = self.cfg.page_tokens
        n_shared = self.shared_head // pt
        for a, b in ((0, 1), (2, 3)):
            ca = self.pm.chains.get(a, [])
            cb = self.pm.chains.get(b, [])
            n = min(len(ca), len(cb), n_shared)
            assert ca[:n] == cb[:n]
            if len(ca) > n_shared and len(cb) > n_shared:
                assert ca[n_shared] != cb[n_shared]
        c0, c2 = self.pm.chains.get(0, []), self.pm.chains.get(2, [])
        if c0 and c2:                   # different groups never share
            assert c0[0] != c2[0]

    def run(self, steps: int = 80) -> None:
        ops = ([self.op_extend] * 3 + [self.op_insert] * 4
               + [self.op_plan_exec] * 4 + [self.op_finish] * 2
               + [self.op_release] + [self.op_drop])
        for sid in range(N_SESSIONS):   # seed every stream past the head
            self._extend(sid, self.shared_head
                         + self.rng.randint(1, 2 * self.cfg.page_tokens))
        self.check()
        for _ in range(steps):
            self.rng.choice(ops)()
            self.check()
        self.check_dedup()


@seeded_property
def test_pool_properties(seed):
    PoolMachine(seed).run()


# ---------------------------------------------------------------------------
# focused unit checks of the plan math
# ---------------------------------------------------------------------------

def _manager(pt=4, hbm=64, host=64) -> PoolManager:
    return PoolManager(KVPoolConfig(page_tokens=pt, hbm_pages=hbm,
                                    host_pages=host))


def _extend_const(pm, sid, upto):
    pm.extend_stream(sid, upto, lambda lo, n: [("t", sid, j)
                                               for j in range(lo, lo + n)])


def test_plan_stops_at_first_absent_page():
    pm, w = _manager(), ("decode", 0)
    _extend_const(pm, 0, 12)
    chain = pm.chains[0]
    assert len(chain) == 3
    pool = pm.pool(w)
    pool.insert(chain[0], 0, 4, 0)
    pool.insert(chain[2], 8, 12, 0)     # hole at page 1: unreachable
    plan = pm.plan_for(w, 0, 12)
    assert plan.pages == (chain[0],)
    assert (plan.hit_tokens, plan.spilled_tokens, plan.miss_tokens) \
        == (4, 0, 8)


def test_degenerate_plans():
    pm = _manager()
    assert pm.plan_for(("decode", 0), 0, 0) == miss_plan(0)
    assert pm.plan_for(("decode", 0), 0, -3) == miss_plan(0)
    p = miss_plan(7)
    assert (p.prefix_tokens, p.miss_tokens, p.total_tokens) == (0, 7, 7)
    # partial trailing page is never addressable
    _extend_const(pm, 1, 6)
    assert len(pm.chains[1]) == 1
    pm.insert_range(("decode", 0), 1, 0, 6, None)
    plan = pm.plan_for(("decode", 0), 1, 6)
    assert plan.prefix_tokens == 4 and plan.miss_tokens == 2


def test_dedup_shares_one_physical_page():
    pm, w = _manager(), ("decode", 0)
    for sid in (0, 1):                  # identical content, two sessions
        pm.extend_stream(sid, 8, lambda lo, n: list(range(lo, lo + n)))
        pm.insert_range(w, sid, 0, 8, None)
    assert pm.chains[0] == pm.chains[1]
    pool = pm.pool(w)
    assert len(pool.pages) == 2         # 8 tokens / 4-token pages, ONE copy
    for key in pm.chains[0]:
        assert pool.pages[key].refs == {0: 1, 1: 1}
    pm.audit()


def test_recovery_plan_clamped_strictly_below_total():
    pm, w = _manager(), ("decode", 0)
    _extend_const(pm, 0, 8)
    pm.insert_range(w, 0, 0, 8, None)
    full = pm.plan_for(w, 0, 8)
    assert full.prefix_tokens == 8      # fully resident
    rec = pm.recovery_plan(w, 0, 8)
    assert rec.prefix_tokens == 4 and rec.miss_tokens == 4
    assert rec.pages == full.pages[:1]  # dropped page returns as a miss


def test_lru_spill_promote_and_pinning():
    pm, w = _manager(pt=4, hbm=2, host=8), ("decode", 0)
    _extend_const(pm, 0, 12)
    chain = pm.chains[0]
    pm.insert_range(w, 0, 0, 12, None)  # 3 pages into a 2-page HBM tier
    pool = pm.pool(w)
    assert pool.tier_of(chain[0]) == TIER_HOST      # LRU page spilled
    assert pool.count(TIER_HBM) == 2
    plan = pm.plan_for(w, 0, 12)
    assert plan.spilled_tokens == 4 and plan.hit_tokens == 8
    pm.execute_plan(w, 0, plan, None)   # touch: promote-on-touch + pins
    assert pool.tier_of(chain[0]) == TIER_HBM
    # all three pages pinned: over capacity but nothing may be demoted
    assert pool.count(TIER_HBM) == 3
    assert all(pool.pages[k].pins == 1 for k in chain)
    _extend_const(pm, 1, 4)
    pm.insert_range(w, 1, 0, 4, None)   # insert under full pins: overflow
    assert all(pool.pages[k].tier == TIER_HBM for k in chain)
    pm.finish_chunk(w, plan)            # pins released
    assert all(pool.pages[k].pins == 0 for k in chain)
    _extend_const(pm, 1, 8)
    pm.insert_range(w, 1, 4, 8, None)   # now the LRU spill can proceed
    assert pool.count(TIER_HBM) <= 2 + 1
    pm.audit()


def test_release_keeps_pages_resident_for_later_sessions():
    pm, w = _manager(), ("decode", 0)
    pm.extend_stream(0, 8, lambda lo, n: list(range(lo, lo + n)))
    pm.insert_range(w, 0, 0, 8, None)
    pm.release_session(0)               # refcount 0, still resident
    pm.extend_stream(1, 8, lambda lo, n: list(range(lo, lo + n)))
    plan = pm.plan_for(w, 1, 8)
    assert plan.prefix_tokens == 8      # the NEXT session still hits
    pm.audit()


# ---------------------------------------------------------------------------
# material store: spill -> promote round-trips byte-identical
# ---------------------------------------------------------------------------

def _extract_tree(lo, hi, seed=0):
    """A minimal well-formed extract: seq leaves are [1, n, ...] slices,
    ``length`` is the whole-state leaf ``concat_extracts`` re-pins."""
    rng = np.random.default_rng(seed)
    n = hi - lo
    return {
        "k": rng.standard_normal((1, n, 2, 3)).astype(np.float32),
        "v": rng.standard_normal((1, n, 2, 3)).astype(np.float32),
        "pos_full": np.arange(lo, hi, dtype=np.int32).reshape(1, n),
        "length": np.array([hi], dtype=np.int32),
    }


def _leaves(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves(v, path + (k,))
    else:
        yield path, np.asarray(tree)


def _copy_tree(tree):
    if isinstance(tree, dict):
        return {k: _copy_tree(v) for k, v in tree.items()}
    return np.copy(np.asarray(tree))


def _assert_trees_identical(a, b):
    la, lb = dict(_leaves(a)), dict(_leaves(b))
    assert la.keys() == lb.keys()
    for path, x in la.items():
        y = lb[path]
        assert x.dtype == y.dtype and x.shape == y.shape, path
        assert np.array_equal(x, y), path


def test_material_spill_promote_round_trip():
    from repro.serving.kv_pool import MaterialStore
    from repro.serving.kv_transfer import transfer_bytes

    store, w = MaterialStore(), ("decode", 0)
    tree = _extract_tree(0, 8)
    store.stage(w, [(0, 8, tree)])
    pages = [Page(key="p0", lo=0, hi=4), Page(key="p1", lo=4, hi=8)]
    for p in pages:
        store.on_insert(w, p)
    orig = {p.key: _copy_tree(store.tiers[w]["hbm"][p.key]) for p in pages}

    store.on_spill(w, pages[0])
    assert "p0" in store.tiers[w]["host"] and "p0" not in store.tiers[w]["hbm"]
    store.on_promote(w, pages[0])
    assert "p0" in store.tiers[w]["hbm"] and "p0" not in store.tiers[w]["host"]
    for p in pages:                     # byte-identical after the round trip
        _assert_trees_identical(orig[p.key], store.tiers[w]["hbm"][p.key])
    # both directions measured into (bytes, seconds) samples
    nbytes = transfer_bytes(store.tiers[w]["hbm"]["p0"])
    assert store.spill_samples == [(nbytes, pytest.approx(
        store.spill_samples[0][1]))]
    assert store.promote_samples[0][0] == nbytes
    assert store.spill_bytes == store.promote_bytes == nbytes

    # read side: the assembled plan serves the identical byte ranges
    plan = CachePlan(hit_tokens=8, pages=("p0", "p1"))
    out = store.assemble(w, plan)
    _assert_trees_identical(tree, out)
    assert store.hit_bytes == transfer_bytes(out)
    # a missing page voids the plan (caller falls back to the lazy read)
    assert store.assemble(w, CachePlan(hit_tokens=4,
                                       pages=("p0", "missing"))) is None


def test_material_insert_requires_full_coverage():
    from repro.serving.kv_pool import MaterialStore
    store, w = MaterialStore(), ("decode", 0)
    store.stage(w, [(0, 6, _extract_tree(0, 6))])
    store.on_insert(w, Page(key="partial", lo=4, hi=8))
    assert "partial" not in store.tiers.get(w, {"hbm": {}})["hbm"]
    store.on_insert(w, Page(key="covered", lo=0, hi=4))
    assert "covered" in store.tiers[w]["hbm"]


# ---------------------------------------------------------------------------
# the §17 recovery fix, pinned on both backends with an injected failure:
# a rebind target holding the (deduped) prefix re-reads only the miss tail
# ---------------------------------------------------------------------------

KV_KW = dict(kv_pool=True, kv_page_tokens=8, kv_hbm_pages=64,
             kv_host_pages=64)
PF, DC, SHARED = 24, 6, 16


def _spy_recovery(runtime, captured):
    orig = runtime.backend.make_recovery_task

    def spy(session, task, now, pending, decode_worker=None, plan=None):
        rtask = orig(session, task, now, pending, decode_worker, plan)
        captured.append((rtask, plan))
        return rtask

    runtime.backend.make_recovery_task = spy


def test_modeled_recovery_attaches_resident_prefix():
    sessions = []
    for i in range(2):
        # gap > round-0 duration: session 0 is resident on decode 0 when
        # session 1 binds, so least-loaded puts session 1 on decode 1
        s = Session(session_id=i, arrival_time=i * 60.0,
                    rounds=[RoundSpec(PF, DC, env_delay=300.0),
                            RoundSpec(PF, DC, env_delay=0.0)])
        s.prefix_group = (0, SHARED)
        sessions.append(s)
    dep = Deployment((WorkerGroup(1, 1),), (WorkerGroup(1, 2),))
    sim = Simulation(PerfModel(get_config("qwen3-32b")), dep, sessions,
                     SLOSpec(10.0, 10.0),
                     SimConfig(scheduler="dynamo", seed=0,
                               routing=RoutingConfig(ttft_thres=10.0,
                                                     itl_thres=10.0),
                               **KV_KW),
                     failures=[(150.0, "decode", 0)])
    sim.coordinator.record_decisions = True
    captured = []
    _spy_recovery(sim.runtime, captured)
    sim.run()

    assert sim.coordinator.rebinds == 1 and len(captured) == 1
    rtask, rplan = captured[0]
    # session 0's context was 24 prompt + 6 decoded tokens; the survivor
    # holds session 1's pages, whose first SHARED tokens dedup with ours —
    # recovery re-reads only the miss suffix, not the full history
    assert rtask.l_hist == SHARED
    assert rplan.hit_tokens == SHARED and rplan.miss_tokens > 0
    assert (0, 1, SHARED, "cache_hit", 1) in sim.coordinator.decision_log
    assert all(s.finish_time is not None for s in sessions)
    sim.runtime._pool.audit()


def test_live_recovery_attaches_resident_prefix():
    from repro.serving import (ClusterSpec, LiveCluster, SchedPolicy,
                               make_live_sessions)
    cfg = get_config("qwen2.5-14b").reduced()
    cl = LiveCluster(cfg, spec=ClusterSpec(n_prefill=1, n_decode=2,
                                           max_slots=4, max_len=256),
                     policy=SchedPolicy(scheduler="dynamo", **KV_KW),
                     slo=SLOSpec(10.0, 10.0), seed=0, profile=False)
    cl.coordinator.record_decisions = True
    sessions = make_live_sessions(cfg, num_sessions=2, rounds=2,
                                  prefill_len=PF, decode_len=DC,
                                  arrival_gap=60.0, shared_prefix=SHARED)
    for s in sessions:                  # a wide env window to fail inside
        s.rounds = [RoundSpec(r.prefill_len, r.decode_len,
                              env_delay=300.0 if i == 0 else 0.0)
                    for i, r in enumerate(s.rounds)]
    captured = []
    _spy_recovery(cl.runtime, captured)
    cl.fail_worker("decode", 0, at=150.0)
    r = cl.run_trace(sessions)

    assert r.rebinds == 1 and len(captured) == 1
    rtask, rplan = captured[0]
    assert rtask.l_hist == SHARED       # live attach, not full re-read
    assert rplan.hit_tokens == SHARED
    assert (0, 1, SHARED, "cache_hit", 1) in cl.coordinator.decision_log
    # the attached prefix was MATERIALLY assembled from the shared pages
    assert cl.kv_store.hit_bytes > 0
    assert all(s.finish_time is not None for s in sessions)
    assert all(d.mem_tokens == 0 for d in cl.decode_workers)
    cl.runtime._pool.audit()


def test_live_token_level_dedup():
    """Token-level verification of dedup soundness on the live backend:
    chain keys are equal exactly where the real token ids are equal."""
    from repro.serving import (ClusterSpec, LiveCluster, SchedPolicy,
                               make_live_sessions)
    cfg = get_config("qwen2.5-14b").reduced()
    cl = LiveCluster(cfg, spec=ClusterSpec(n_prefill=1, n_decode=1,
                                           max_slots=4, max_len=256),
                     policy=SchedPolicy(scheduler="ampd", **KV_KW),
                     slo=SLOSpec(10.0, 10.0), seed=0, profile=False)
    sessions = make_live_sessions(cfg, num_sessions=2, rounds=1,
                                  prefill_len=PF, decode_len=4,
                                  arrival_gap=100.0, shared_prefix=SHARED)
    cl.run_trace(sessions)
    pm = cl.runtime._pool
    # the streams hold the actual token ids, equal over the shared head
    for sid, s in enumerate(sessions):
        assert pm.streams[sid][:PF] == [int(t) for t in s.prompt_tokens[0]]
    c0, c1 = pm.chains[0], pm.chains[1]
    n_shared = SHARED // KV_KW["kv_page_tokens"]
    assert c0[:n_shared] == c1[:n_shared]       # same tokens, same pages
    assert c0[n_shared] != c1[n_shared]         # unique tails diverge
    # one physical copy of each shared page in the material store
    hbm = cl.kv_store.tiers[("decode", 0)]["hbm"]
    for key in c0[:n_shared]:
        assert key in hbm
    pool = pm.pool(("decode", 0))
    assert len(pool.pages) == len(set(c0) | set(c1))
    pm.audit()
