"""Planner v2: T_fused fit, ChunkTuner, joint chunk/deployment search,
and degenerate-deployment guards (DESIGN.md §11)."""

import pytest

from repro.configs import get_config
from repro.core import (
    Deployment,
    PerfModel,
    PlanningError,
    SimConfig,
    Simulation,
    SLOSpec,
    WorkerGroup,
    plan,
)
from repro.core.planner import ILPSolution
from repro.core.routing import RoutingConfig
from repro.runtime import ChunkTuner
from repro.workloads import make_trace


@pytest.fixture(scope="module")
def perf():
    return PerfModel(get_config("qwen3-32b"))


# ---------------------------------------------------------------------------
# T_fused
# ---------------------------------------------------------------------------


def test_fit_fused_recovers_synthetic_coefficients():
    # fresh instance: fit_fused mutates, and the module fixture is shared
    perf = PerfModel(get_config("qwen3-32b"))
    true = dict(alpha=2.5e-3, bp=1.7e-4, gp=3.0e-8, bd=2.0e-4, gd=5.0e-8)

    def t(l_hist, l_incr, b, ctx):
        return (
            true["alpha"]
            + true["bp"] * l_incr
            + true["gp"] * l_incr * (l_hist + l_incr / 2.0)
            + true["bd"] * b
            + true["gd"] * b * ctx
        )

    samples = [
        (h, n, b, float(ctx), t(h, n, b, ctx))
        for h in (0, 512, 2048)
        for n in (128, 512, 1024)
        for b in (0, 4, 16)
        for ctx in (256, 4096)
    ]
    perf.fit_fused(4, samples)
    c = perf.fused[4]
    assert c.alpha == pytest.approx(true["alpha"], rel=1e-6)
    assert c.beta_pre == pytest.approx(true["bp"], rel=1e-6)
    assert c.gamma_pre == pytest.approx(true["gp"], rel=1e-6)
    assert c.beta_dec == pytest.approx(true["bd"], rel=1e-6)
    assert c.gamma_dec == pytest.approx(true["gd"], rel=1e-6)
    # and the cost function evaluates the fitted model
    assert perf.t_fused(512, 256, 8, 4, 1024.0) == pytest.approx(
        t(512, 256, 8, 1024.0), rel=1e-6
    )


def test_t_fused_analytic_matches_marginal_decode_composition():
    perf = PerfModel(get_config("qwen3-32b"))
    l_hist, l_incr, b, ctx, tp = 1024, 512, 6, 2048.0, 4
    marginal = perf.t_dec(b, tp, ctx) - perf.t_dec(0, tp, ctx)
    expect = perf.t_pre(l_hist, l_incr, tp) + marginal
    assert perf.t_fused(l_hist, l_incr, b, tp, ctx) == pytest.approx(expect)


def test_fit_prefill_refreshes_derived_fused_coefficients():
    perf = PerfModel(get_config("qwen3-32b"))
    samples = [
        (h, n, 5e-3 + 4e-4 * n + 1e-8 * n * (h + n / 2.0))
        for h in (0, 256, 1024)
        for n in (64, 256, 1024)
    ]
    perf.fit_prefill(4, samples)
    assert perf.fused[4].alpha == pytest.approx(perf.pre[4].alpha)
    assert perf.fused[4].beta_pre == pytest.approx(perf.pre[4].beta)


# ---------------------------------------------------------------------------
# ChunkTuner
# ---------------------------------------------------------------------------


def test_chunk_tuner_monotone_in_itl_slo(perf):
    slos = (0.5, 0.3, 0.15, 0.08, 0.04, 0.02, 0.01)
    chunks = [
        ChunkTuner(perf, itl_slo=s).chunk_for(4, 8, 4096.0, 2048) for s in slos
    ]
    for tight, loose in zip(chunks[1:], chunks):
        assert tight <= loose, f"tighter SLO grew the chunk: {chunks}"
    # a meaningfully looser SLO must actually buy a bigger chunk
    assert chunks[0] > chunks[-1]


def test_chunk_tuner_monotone_in_batch_and_history(perf):
    tuner = ChunkTuner(perf, itl_slo=0.05)
    by_batch = [tuner.chunk_for(4, b, 8192.0, 1024) for b in (0, 8, 32, 128)]
    assert all(a >= b for a, b in zip(by_batch, by_batch[1:]))
    by_hist = [tuner.chunk_for(4, 4, 2048.0, h) for h in (0, 4096, 65536)]
    assert all(a >= b for a, b in zip(by_hist, by_hist[1:]))


def test_chunk_tuner_bounds_and_quantum(perf):
    tuner = ChunkTuner(perf, itl_slo=1e-6)  # impossible budget
    assert tuner.chunk_for(4, 64, 65536.0, 65536) == tuner.min_chunk
    big = ChunkTuner(perf, itl_slo=1e3).chunk_for(16, 0, 0.0, 0)
    assert big == ChunkTuner(perf, itl_slo=1e3).max_chunk
    c = ChunkTuner(perf, itl_slo=0.08).chunk_for(4, 4, 2048.0, 512)
    assert c % ChunkTuner(perf, itl_slo=0.08).quantum == 0


# ---------------------------------------------------------------------------
# Degenerate deployments raise
# ---------------------------------------------------------------------------


def test_ilp_solution_empty_side_raises():
    sol = ILPSolution(x={4: 0}, y={4: 2}, z=1.0, status="optimal",
                      solve_seconds=0.0)
    with pytest.raises(PlanningError):
        sol.deployment()
    failed = ILPSolution(x={}, y={}, z=float("inf"), status="failed:infeasible",
                         solve_seconds=0.0)
    with pytest.raises(PlanningError):
        failed.deployment()


def test_plan_rejects_budget_below_one_worker_pair(perf):
    slo = SLOSpec(ttft_thres=3.0, itl_thres=0.15)
    with pytest.raises(PlanningError):
        plan(perf, lambda: [], N=1, slo=slo, degrees=(2, 4))
    with pytest.raises(PlanningError):
        plan(perf, lambda: [], N=3, slo=slo, degrees=(2, 4))


# ---------------------------------------------------------------------------
# Joint chunk/deployment planning + adaptive runtime
# ---------------------------------------------------------------------------


def test_joint_plan_returns_chunked_deployment(perf):
    slo = SLOSpec(ttft_thres=3.0, itl_thres=0.15)
    res = plan(
        perf,
        lambda: make_trace("hotpotqa", num_sessions=12, arrival_rate=0.8,
                           seed=5),
        N=4,
        slo=slo,
        degrees=(1, 2),
        max_candidates=4,
        seed=5,
        scheduler="ampd-chunked",
        chunk_grid=(256, 512),
    )
    assert res.ilp.status == "optimal"
    assert set(res.chunk_by_degree) == {1, 2}
    assert all(c in (256, 512) for c in res.chunk_by_degree.values())
    dep, att, _ = res.ranked[0]
    assert att > 0.0
    assert all(g.chunk_tokens in (256, 512) for g in dep.decode)
    ilp_dep = res.ilp.deployment(res.chunk_by_degree)
    assert all(g.chunk_tokens in (256, 512) for g in ilp_dep.decode)
    assert "C=" in ilp_dep.label()


def test_adaptive_chunk_simulation_completes(perf):
    slo = SLOSpec(ttft_thres=6.0, itl_thres=0.1)
    dep = Deployment((), (WorkerGroup(4, 2),))
    cfg = SimConfig(
        scheduler="ampd-chunked",
        adaptive_chunk=True,
        seed=11,
        routing=RoutingConfig(ttft_thres=slo.ttft_thres,
                              itl_thres=slo.itl_thres),
    )
    sessions = make_trace("gaia", num_sessions=10, arrival_rate=0.5, seed=11)
    res = Simulation(perf, dep, sessions, slo, cfg).run()
    assert all(s.finish_time is not None for s in sessions)
    assert res.avg_itl > 0.0


def test_decode_chunks_expansion_for_live_cluster():
    dep = Deployment(
        (WorkerGroup(1, 1),),
        (WorkerGroup(2, 2, 256), WorkerGroup(1, 1, 128)),
    )
    assert dep.decode_chunks() == (256, 256, 128)


def test_per_group_chunk_tokens_reach_live_workers():
    from repro.configs import get_config as gc
    from repro.serving.cluster import LiveCluster
    from repro.serving.config import ClusterSpec, SchedPolicy

    cfg = gc("qwen2.5-14b").reduced()
    cl = LiveCluster(
        cfg,
        spec=ClusterSpec(n_prefill=1, n_decode=2, max_slots=1, max_len=64),
        policy=SchedPolicy(scheduler="ampd-chunked",
                           decode_chunk_tokens=(16, 8)),
        profile=False,
    )
    assert [w.chunk_tokens for w in cl.decode_workers] == [16, 8]
    assert cl.runtime._chunked


def test_per_group_chunk_tokens_reach_workers(perf):
    slo = SLOSpec(ttft_thres=6.0, itl_thres=0.1)
    dep = Deployment(
        (WorkerGroup(2, 1),),
        (WorkerGroup(2, 2, 128),),
    )
    cfg = SimConfig(
        scheduler="ampd-chunked",
        seed=3,
        routing=RoutingConfig(ttft_thres=slo.ttft_thres,
                              itl_thres=slo.itl_thres),
    )
    sessions = make_trace("hotpotqa", num_sessions=8, arrival_rate=1.0, seed=3)
    sim = Simulation(perf, dep, sessions, slo, cfg)
    assert all(w.chunk_tokens == 128 for w in sim.decode_workers)
    sim.run()
    assert all(s.finish_time is not None for s in sessions)
