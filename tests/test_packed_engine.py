"""Packed (ragged) fused path: engine parity, upload regression, cluster
decision-log parity, and T_fused cost-layer inheritance (DESIGN.md §15)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.perf_model import PerfModel
from repro.core.types import PrefillTask, RoundSpec, SLOSpec
from repro.models.packed import supports_packed
from repro.runtime.chunk_tuner import ChunkTuner
from repro.serving.cluster import LiveCluster, make_live_sessions
from repro.serving.config import ClusterSpec, SchedPolicy
from repro.serving.engine import Engine, chunk_limit, profile_engine
from repro.serving.workers import LiveDecodeWorker, LiveSession


@pytest.fixture(scope="module", params=["qwen3-32b", "gemma2-2b"])
def engine(request):
    cfg = get_config(request.param).reduced()
    return Engine(cfg, max_len=128, key=jax.random.PRNGKey(0))


def _seed_histories(eng, B, hists, rng):
    cache = eng.new_cache(B)
    V = eng.cfg.vocab_size
    for i, h in enumerate(hists):
        toks = np.full((B, max(hists) + 3), -1, np.int32)
        toks[i, :h] = rng.integers(0, V, h)
        cache, _, _ = eng.run_chunk(cache, jnp.asarray(toks))
    return cache


def test_run_packed_matches_dense_fused_step(engine):
    """One packed launch == the dense rectangle: same cache lengths, same
    position maps, same per-segment logits."""
    eng = engine
    rng = np.random.default_rng(0)
    V = eng.cfg.vocab_size
    B = 4
    cache_d = _seed_histories(eng, B, [13, 7, 21, 5], rng)
    cache_p = jax.tree.map(jnp.copy, cache_d)

    ptoks = rng.integers(0, V, 11).astype(np.int32)
    dtoks = rng.integers(0, V, 3).astype(np.int32)
    chunk = np.full((B, 16), -1, np.int32)
    chunk[0, :11] = ptoks
    for i in range(3):
        chunk[i + 1, 0] = dtoks[i]
    cache_d, logits_d, _ = eng.run_chunk(cache_d, jnp.asarray(chunk))

    segs = [(0, ptoks)] + [(i + 1, dtoks[i:i + 1]) for i in range(3)]
    cache_p, seg_logits, _ = eng.run_packed(cache_p, segs)

    assert (np.asarray(cache_d["length"])
            == np.asarray(cache_p["length"])).all()
    np.testing.assert_allclose(np.asarray(seg_logits, np.float32),
                               np.asarray(logits_d, np.float32),
                               atol=2e-4, rtol=2e-4)
    for k in ("pos_full", "pos_ring"):
        if k in cache_d:
            md, mp = np.asarray(cache_d[k]), np.asarray(cache_p[k])
            # slots never written differ only in which invalid they carry
            assert ((md == mp) | ((md < -2**29) & (mp < -2**29))).all()


def test_run_packed_rejects_bad_packs(engine):
    eng = engine
    cache = eng.new_cache(2)
    with pytest.raises(AssertionError):
        eng.run_packed(cache, [])
    with pytest.raises(AssertionError):   # duplicate rows
        eng.run_packed(cache, [(0, np.zeros(4, np.int32)),
                               (0, np.zeros(1, np.int32))])
    with pytest.raises(AssertionError):   # over the chunk limit
        lim = chunk_limit(eng.cfg, eng.max_len)
        eng.run_packed(cache, [(0, np.zeros(lim + 1, np.int32))])


def test_packed_unsupported_arch_gated():
    cfg = get_config("mamba2-130m").reduced()
    assert not supports_packed(cfg)
    eng = Engine(cfg, max_len=64, key=jax.random.PRNGKey(0))
    assert not eng.supports_packed
    with pytest.raises(AssertionError):
        eng.run_packed(eng.new_cache(1), [(0, np.zeros(4, np.int32))])
    # the worker silently falls back to dense even when packed is requested
    w = LiveDecodeWorker(0, eng, max_slots=2, packed=True)
    assert not w.packed


# ---------------------------------------------------------------------------
# upload accounting (satellite: sub-chunk waste fix)
# ---------------------------------------------------------------------------

def _mk_task(sid, toks):
    return PrefillTask(session_id=sid, round_idx=0, l_hist=0,
                       l_incr=len(toks), enqueue_time=0.0, arrival_time=0.0,
                       is_initial=True)


def _fused_scenario(cfg, packed, n_chunk=50, n_dec=3, max_slots=4):
    eng = Engine(cfg, max_len=128, key=jax.random.PRNGKey(0))
    w = LiveDecodeWorker(0, eng, max_slots=max_slots, packed=packed)
    rng = np.random.default_rng(1)
    V = cfg.vocab_size
    batch = []
    for i in range(1, n_dec + 1):
        toks = rng.integers(0, V, 6).astype(np.int32)
        s = LiveSession(session_id=i, arrival_time=0.0,
                        rounds=[RoundSpec(6, 4)], prompt_tokens=[toks])
        w.slots[i] = s
        s.slot = i
        _, first = w.local_prefill(_mk_task(i, toks), s)
        s.last_token = first
        batch.append(s)
    toks0 = rng.integers(0, V, n_chunk).astype(np.int32)
    s0 = LiveSession(session_id=9, arrival_time=0.0,
                     rounds=[RoundSpec(n_chunk, 4)], prompt_tokens=[toks0])
    w.slots[0] = s0
    s0.slot = 0
    up0 = eng.tokens_uploaded
    dt, first, toks = w.fused_step(_mk_task(9, toks0), s0, batch)
    return eng, w, s0, batch, first, toks, eng.tokens_uploaded - up0


def test_fused_step_upload_regression():
    """A fused step spanning multiple sub-chunks must upload
    sum(width_i + max_slots) token elements — NEVER re-materialize the
    (max_slots, width) rectangle for sub-chunks whose decode rows do not
    advance (the old path shipped n_sub * max_slots * width)."""
    cfg = get_config("gemma2-2b").reduced()   # window 32 < chunk 50 -> 2 subs
    n_chunk, max_slots = 50, 4
    eng, w, *_, uploaded = _fused_scenario(cfg, packed=False,
                                           n_chunk=n_chunk,
                                           max_slots=max_slots)
    lim = chunk_limit(cfg, eng.max_len)
    assert lim < n_chunk, "scenario must span >1 sub-chunk"
    m = eng.pad_mult
    expect, rect = 0, 0
    for lo in range(0, n_chunk, lim):
        width = ((min(lim, n_chunk - lo) + m - 1) // m) * m
        expect += width + max_slots
        rect += max_slots * width
    assert uploaded == expect, (uploaded, expect)
    assert uploaded < rect            # strictly better than the rectangle


def test_packed_fused_step_upload_counts():
    """The packed step uploads one shape-bucketed stream per sub-chunk."""
    from repro.kernels.ragged_fused.ops import pack_layout

    cfg = get_config("gemma2-2b").reduced()
    n_chunk, n_dec = 50, 3
    eng, w, *_, uploaded = _fused_scenario(cfg, packed=True, n_chunk=n_chunk,
                                           n_dec=n_dec)
    lim = chunk_limit(cfg, eng.max_len)
    expect = 0
    first = True
    for lo in range(0, n_chunk, lim):
        lens = [min(lim, n_chunk - lo)] + ([1] * n_dec if first else [])
        _, total = pack_layout(lens, eng.pack_align)
        expect += eng.packed_bucket(total)
        first = False
    assert uploaded == expect, (uploaded, expect)


def test_packed_vs_dense_worker_tokens():
    """Same tokens out of both fused paths, including multi-sub chunks."""
    cfg = get_config("gemma2-2b").reduced()
    _, _, s0_d, batch_d, first_d, toks_d, _ = _fused_scenario(cfg, False)
    _, _, s0_p, batch_p, first_p, toks_p, _ = _fused_scenario(cfg, True)
    assert first_d == first_p
    assert toks_d == toks_p


# ---------------------------------------------------------------------------
# cluster decision-log parity (packed=True vs packed=False)
# ---------------------------------------------------------------------------

def _run_cluster(cfg, packed):
    cl = LiveCluster(cfg,
                     spec=ClusterSpec(n_prefill=1, n_decode=1, max_slots=4,
                                      max_len=128),
                     policy=SchedPolicy(packed=packed, chunk_tokens=16),
                     profile=False, slo=SLOSpec(10.0, 10.0))
    cl.coordinator.record_decisions = True
    # arrival gap >> any engine duration: event order (hence the decision
    # log) is protocol-determined, not timing-determined — the same device
    # that makes the multiproc golden stable makes this parity exact.
    sessions = make_live_sessions(cfg, num_sessions=3, rounds=2,
                                  prefill_len=20, decode_len=4,
                                  arrival_gap=100.0)
    res = cl.run(sessions)
    return (res, list(cl.coordinator.decision_log),
            [list(map(int, s.generated)) for s in sessions])


def test_cluster_decision_log_parity():
    cfg = get_config("gemma2-2b").reduced()
    res_d, log_d, toks_d = _run_cluster(cfg, packed=False)
    res_p, log_p, toks_p = _run_cluster(cfg, packed=True)
    assert not res_d.packed and res_p.packed
    assert log_d == log_p
    assert toks_d == toks_p
    assert res_p.tokens_uploaded > 0
    # SLO accounting survives the swap
    assert res_p.slo_attainment == res_d.slo_attainment == 1.0


# ---------------------------------------------------------------------------
# cost-layer inheritance: packed profile -> T_fused -> tuner
# ---------------------------------------------------------------------------

def test_t_fused_refit_and_tuner_inheritance():
    cfg = get_config("qwen3-32b").reduced()
    eng = Engine(cfg, max_len=256, key=jax.random.PRNGKey(0))
    assert eng.supports_packed

    perf_d, perf_p = PerfModel(cfg), PerfModel(cfg)
    up0 = eng.tokens_uploaded
    profile_engine(eng, perf_d, tp=1, prefill_lens=(16, 32, 64),
                   hist_lens=(0, 32), batches=(1, 3), fused=True,
                   packed=False)
    up1 = eng.tokens_uploaded
    profile_engine(eng, perf_p, tp=1, prefill_lens=(16, 32, 64),
                   hist_lens=(0, 32), batches=(1, 3), fused=True,
                   packed=True)
    # the packed profile really drove run_packed (uploads counted per pack;
    # the dense profile calls run_chunk directly and counts nothing)
    assert up1 == up0 and eng.tokens_uploaded > up1

    # both fits are MEASURED (no analytic re-derivation)
    assert 1 in perf_d._fused_fitted and 1 in perf_p._fused_fitted

    # sane, finite fits at the piggyback shape.  NOTE: the profiler clamps
    # fused sampling to batch <= 3, where the CPU ref path's gather overhead
    # can eat the packing win — the packed>dense PERF gate lives in
    # benchmarks/kernel_bench.py --smoke at the full 8-row piggyback shape;
    # here we only bound gross regressions (CI timing, not a benchmark).
    shape = dict(l_hist=32, l_incr=64, batch=3, tp=1, avg_ctx=32.0)
    t_d, t_p = perf_d.t_fused(**shape), perf_p.t_fused(**shape)
    assert t_p > 0.0 and t_d > 0.0
    assert t_p <= 3.0 * t_d, (t_p, t_d)

    # ChunkTuner inverts whichever fit it is handed — T_fused-driven chunk
    # decisions consume the MEASURED packed coefficients, and a larger ITL
    # budget can never shrink the chunk
    tuner = ChunkTuner(perf_p, itl_slo=4.0 * t_p)
    ch = tuner.chunk_for(1, 3, avg_ctx=32.0)
    ch_big = ChunkTuner(perf_p, itl_slo=40.0 * t_p).chunk_for(
        1, 3, avg_ctx=32.0)
    assert ch >= tuner.min_chunk
    assert ch_big >= ch
