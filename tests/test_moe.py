"""MoE layer: ragged sort-based dispatch vs an explicit dense loop oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import activate, init_from_template
from repro.models.moe import _local_moe, _topk_route, moe_template


def _dense_oracle(cfg, p, xf):
    """Loop over experts; weight by top-k softmax gains."""
    T, d = xf.shape
    gains, ids, _ = _topk_route(cfg, p["router"], xf)
    out = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(cfg.num_experts_per_tok):
            e = int(ids[t, j])
            h = activate(cfg.activation,
                         xf[t] @ p["wg"][e], xf[t] @ p["wi"][e])
            out[t] += float(gains[t, j]) * np.asarray(h @ p["wo"][e])
    return out


def test_ragged_moe_matches_dense_loop():
    cfg = get_config("mixtral-8x7b").reduced()
    tmpl = moe_template(cfg)
    p = init_from_template(tmpl, jax.random.PRNGKey(0), "float32")
    xf = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model))
    out, aux, group_sizes = _local_moe(cfg, p, xf)
    ref = _dense_oracle(cfg, p, xf)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)
    assert int(group_sizes.sum()) == 12 * cfg.num_experts_per_tok
    assert float(aux) > 0


def test_moe_in_model_forward_balanced_load_metric():
    cfg = get_config("dbrx-132b").reduced()
    from repro.models import build_model
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                cfg.vocab_size)
    logits, aux = m.forward_train(params, tokens)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux["moe_aux_loss"]) > 0
