"""Unit tests for Alg. 1 (adaptive routing) and the simulator's behaviour
under the scheduling policies, plus fault-tolerance/straggler invariants."""
import random

import pytest

from repro.configs import get_config
from repro.core import (
    Deployment,
    PerfModel,
    RoutingConfig,
    SimConfig,
    Simulation,
    SLOSpec,
    WorkerGroup,
    route_prefill,
    simulate_deployment,
)
from repro.core.simulator import SimWorker, WindowStat
from repro.core.types import PrefillTask
from repro.workloads import make_trace


def _perf():
    return PerfModel(get_config("qwen3-32b"))


def _task(l_hist=0, l_incr=512):
    return PrefillTask(session_id=0, round_idx=0, l_hist=l_hist,
                       l_incr=l_incr, enqueue_time=0.0, arrival_time=0.0)


def _worker(kind, tp=4, ttft=0.0, itl=0.0, queue=(), idx=0):
    w = SimWorker(idx, tp, kind)
    w.windowed_ttft = ttft
    w.windowed_itl = itl
    w.prefill_queue = list(queue)
    return w


def test_routing_prefers_remote_with_ttft_slack():
    cfg = RoutingConfig(alpha=0.9, beta=0.85, ttft_thres=2.0, itl_thres=0.1)
    d = _worker("decode", itl=0.09)
    p = _worker("prefill", ttft=0.5)     # well under alpha * thres
    dec = route_prefill(_task(), d, [p], _perf(), cfg, random.Random(0))
    assert dec.kind == "remote" and dec.reason == "ttft-slack"


def test_routing_falls_back_to_local_on_itl_slack():
    cfg = RoutingConfig(ttft_thres=2.0, itl_thres=0.1)
    d = _worker("decode", itl=0.01)                    # decode nearly idle
    p = _worker("prefill", ttft=1.95)                  # prefill saturated
    dec = route_prefill(_task(), d, [p], _perf(), cfg, random.Random(0))
    assert dec.kind == "local" and dec.reason == "itl-slack"


def test_routing_cost_comparison_picks_cheaper():
    cfg = RoutingConfig(ttft_thres=2.0, itl_thres=0.1)
    perf = _perf()
    d = _worker("decode", tp=4, itl=0.5)               # no slack anywhere
    # a prefill worker with a massive queue should lose to local execution
    busy_q = [_task(l_incr=8000) for _ in range(20)]
    p = _worker("prefill", tp=4, ttft=5.0, queue=busy_q)
    dec = route_prefill(_task(l_incr=256), d, [p], perf, cfg, random.Random(0))
    assert dec.kind == "local" and dec.reason == "cost"
    # and with an idle prefill worker + expensive history, remote wins
    p2 = _worker("prefill", tp=4, ttft=5.0)
    dec2 = route_prefill(_task(l_hist=64, l_incr=4096), d, [p2], perf, cfg,
                         random.Random(0))
    assert dec2.est_cost > 0


def test_routing_skips_dead_workers():
    cfg = RoutingConfig(ttft_thres=2.0, itl_thres=0.1)
    d = _worker("decode", itl=0.09)
    p = _worker("prefill", ttft=0.1)
    p.alive = False
    dec = route_prefill(_task(), d, [p], _perf(), cfg, random.Random(0))
    assert dec.kind == "local"


# ---------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------

DEP = Deployment((WorkerGroup(4, 2),), (WorkerGroup(4, 2),))
SLO = SLOSpec(ttft_thres=3.0, itl_thres=0.15)


@pytest.mark.parametrize("scheduler", ["ampd", "dynamo", "vllm", "continuum",
                                       "ampd-noreorder", "ampd-noroute"])
def test_all_sessions_complete(scheduler):
    sessions = make_trace("hotpotqa", num_sessions=60, arrival_rate=0.8, seed=2)
    r = simulate_deployment(_perf(), DEP, sessions, SLO, scheduler=scheduler)
    assert all(s.finish_time is not None for s in r.sessions)
    # token conservation: every round produced one TTFT and decode_len ITLs
    for s in r.sessions:
        assert len(s.ttfts) == s.num_rounds
        assert len(s.itls) == s.total_decode()


def test_colocated_has_no_remote_and_dynamo_no_local():
    ss = make_trace("toolbench", num_sessions=40, arrival_rate=1.0, seed=3)
    r_v = simulate_deployment(_perf(), DEP, ss, SLO, scheduler="vllm")
    assert r_v.local_fraction == 1.0
    ss = make_trace("toolbench", num_sessions=40, arrival_rate=1.0, seed=3)
    r_d = simulate_deployment(_perf(), DEP, ss, SLO, scheduler="dynamo")
    assert r_d.local_fraction == 0.0


def test_disaggregation_protects_itl():
    """PD interference: co-located ITL >= disaggregated ITL under load."""
    mk = lambda: make_trace("dureader", num_sessions=80, arrival_rate=1.5, seed=4)
    r_d = simulate_deployment(_perf(), DEP, mk(), SLO, scheduler="dynamo")
    r_v = simulate_deployment(_perf(), DEP, mk(), SLO, scheduler="vllm")
    assert r_v.avg_itl > r_d.avg_itl


def test_decode_failure_recovers_sessions():
    ss = make_trace("hotpotqa", num_sessions=40, arrival_rate=0.8, seed=5)
    perf = _perf()
    sim = Simulation(perf, DEP, ss, SLO, SimConfig(scheduler="ampd"),
                     failures=[(10.0, "decode", 0)])
    r = sim.run()
    assert r.recoveries > 0
    assert all(s.finish_time is not None for s in r.sessions)


def test_prefill_failure_reroutes_queue():
    ss = make_trace("dureader", num_sessions=40, arrival_rate=2.0, seed=6)
    sim = Simulation(_perf(), DEP, ss, SLO, SimConfig(scheduler="dynamo"),
                     failures=[(5.0, "prefill", 0)])
    r = sim.run()
    assert all(s.finish_time is not None for s in r.sessions)


def test_straggler_cost_routing_prefers_fast_worker():
    """Alg. 1 lines 6-9: the cost model accounts for worker speed, so a
    4x-slow straggler loses the argmin when no one has slack."""
    cfg = RoutingConfig(ttft_thres=2.0, itl_thres=0.1)
    perf = _perf()
    # decode worker busy with queued local prefills -> local is expensive
    d = _worker("decode", tp=4, itl=0.5,
                queue=[_task(l_incr=4096) for _ in range(4)])
    slow = _worker("prefill", tp=4, ttft=5.0, idx=7)
    slow.speed = 0.25
    fast = _worker("prefill", tp=4, ttft=5.0, idx=3)
    dec = route_prefill(_task(l_incr=4096), d, [slow, fast], perf, cfg,
                        random.Random(0))
    # the decision names the winner by STABLE id, not list position
    assert dec.kind == "remote" and dec.worker_idx == fast.idx


def test_straggler_receives_fewer_tasks_under_load():
    """Cluster-level: under prefill saturation the slow worker's completed-
    task share drops (windowed stats + cost model route around it)."""
    dep = Deployment((WorkerGroup(4, 2),), (WorkerGroup(4, 2),))
    ss = make_trace("gaia", num_sessions=60, arrival_rate=1.0, seed=7)
    slow = {("prefill", 0): 0.25}
    sim = Simulation(_perf(), dep, ss, SLO, SimConfig(scheduler="ampd"),
                     straggler=slow)
    r = sim.run()
    done = [w.tasks_done for w in sim.prefill_workers]
    assert done[0] < done[1]


def test_elastic_scale_up_reduces_pressure():
    ss = make_trace("dureader", num_sessions=60, arrival_rate=2.5, seed=8)
    perf = _perf()
    small = Deployment((WorkerGroup(4, 1),), (WorkerGroup(4, 1),))
    r1 = simulate_deployment(perf, small, ss, SLO, scheduler="ampd")
    ss2 = make_trace("dureader", num_sessions=60, arrival_rate=2.5, seed=8)
    big = Deployment((WorkerGroup(4, 3),), (WorkerGroup(4, 2),))
    r2 = simulate_deployment(perf, big, ss2, SLO, scheduler="ampd")
    assert r2.p95_ttft <= r1.p95_ttft
    assert r2.slo_attainment >= r1.slo_attainment
