"""Shape-aware priority sharding engine: the rules that make one config
serve 64-head (Megatron) and 40/10-head (context/row-parallel) archs."""
import pytest

jax = pytest.importorskip("jax")

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.distributed.sharding import ShardingEnv, make_rules  # noqa: E402
from repro.launch.mesh import make_abstract_mesh, make_worker_mesh  # noqa: E402


@pytest.fixture(scope="module")
def env():
    # 1x1 mesh can't test divisibility; build an abstract 16x16 mesh
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    rules = make_rules(mode="prefill", data_axes=("data",))
    return ShardingEnv(mesh=mesh, rules=rules)


def test_divisible_heads_win(env):
    # command-r: wq (8192, 64, 128) -> heads sharded, attn_in dropped
    spec = env.spec(("attn_in", "heads", "head_dim"), (8192, 64, 128))
    assert spec == P(None, "model", None)


def test_non_divisible_heads_fall_back_to_row_parallel(env):
    # qwen: 40 heads don't divide 16 -> attn_in takes the model axis
    spec = env.spec(("attn_in", "heads", "head_dim"), (5120, 40, 128))
    assert spec == P("model", None, None)


def test_wo_fallback_uses_o_hd(env):
    spec = env.spec(("heads", "o_hd", "embed"), (40, 128, 5120))
    assert spec == P(None, "model", None)
    spec64 = env.spec(("heads", "o_hd", "embed"), (64, 128, 8192))
    assert spec64 == P("model", None, None)


def test_kv_cache_seq_sharding(env):
    # kv_heads=8 never divides 16; kv_seq takes the axis
    spec = env.spec(("batch", "kv_seq", "kv_heads", "head_dim"),
                    (32, 32768, 8, 128))
    assert spec == P("data", "model", None, None)


def test_vocab_padding_dropped(env):
    # mamba2 vocab 50280 is not divisible by 16 -> replicated
    spec = env.spec(("vocab", "embed"), (50280, 768))
    assert spec == P(None, None)
    spec2 = env.spec(("vocab", "embed"), (152064, 5120))
    assert spec2 == P("model", None)


def test_logits_prefer_vocab_over_seq(env):
    spec = env.spec(("batch", "seq", "vocab"), (256, 4096, 152064))
    assert spec == P("data", None, "model")


def test_decode_rules_context_parallel():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    rules = make_rules(mode="decode", data_axes=("data",))
    env = ShardingEnv(mesh=mesh, rules=rules)
    # decode logits (B, H, 1, T): only kv_seq can take the model axis
    spec = env.spec(("batch", "heads", "seq", "kv_seq"), (128, 40, 1, 32768))
    assert spec == P("data", None, None, "model")


def test_batch_unshardable_cells():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    rules = make_rules(mode="decode", data_axes=("data",),
                       batch_shardable=False)
    env = ShardingEnv(mesh=mesh, rules=rules)
    spec = env.spec(("batch", "kv_seq", "kv_heads", "head_dim"),
                    (1, 524288, 1, 256))
    assert spec == P(None, "model", None, None)
