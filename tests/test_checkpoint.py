"""Checkpoint/restart: atomicity, exact resume (state + data cursor)."""
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.training import Trainer, TrainerConfig


def test_roundtrip_exact(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones((2,), np.int32), "d": np.float32(3.5)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"cursor": 42})
    out, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra["cursor"] == 42
    np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), tree["b"]["c"])


def test_retention(tmp_path):
    tree = {"x": np.zeros(3, np.float32)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert len(steps) == 2


def test_data_pipeline_exact_resume():
    p1 = DataPipeline(vocab_size=128, batch_size=2, seq_len=16, seed=3)
    batches = [p1.next_batch()["tokens"] for _ in range(5)]
    st = p1.state()
    after = [p1.next_batch()["tokens"] for _ in range(3)]

    p2 = DataPipeline(vocab_size=128, batch_size=2, seq_len=16, seed=3)
    p2.restore(st)
    again = [p2.next_batch()["tokens"] for _ in range(3)]
    for a, b in zip(after, again):
        np.testing.assert_array_equal(a, b)


def test_trainer_resume_bitwise(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    tc = TrainerConfig(batch_size=2, seq_len=32, steps=6, log_every=3,
                       ckpt_every=3, ckpt_dir=str(tmp_path), seed=1)
    tr = Trainer(cfg, tc)
    tr.run(log=lambda *_: None)
    tr.save()
    final_leaf = np.asarray(next(iter(
        __import__("jax").tree.leaves(tr.state["params"]))))

    # fresh trainer: resume from the final checkpoint; state must match
    tr2 = Trainer(cfg, tc)
    step = tr2.maybe_resume()
    assert step == 6
    leaf2 = np.asarray(next(iter(
        __import__("jax").tree.leaves(tr2.state["params"]))))
    np.testing.assert_array_equal(final_leaf, leaf2)

    # interrupted-run equivalence: run 3 steps + resume-for-3 == run 6
    tc_a = TrainerConfig(batch_size=2, seq_len=32, steps=3, log_every=10,
                         ckpt_every=3, ckpt_dir=str(tmp_path / "a"), seed=2)
    tra = Trainer(cfg, tc_a)
    tra.run(log=lambda *_: None)
    tra.save()
    tc_b = TrainerConfig(batch_size=2, seq_len=32, steps=6, log_every=10,
                         ckpt_every=100, ckpt_dir=str(tmp_path / "a"), seed=2)
    trb = Trainer(cfg, tc_b)
    assert trb.maybe_resume() == 3
    trb.run(log=lambda *_: None)

    tc_c = TrainerConfig(batch_size=2, seq_len=32, steps=6, log_every=10,
                         ckpt_dir=None, seed=2)
    trc = Trainer(cfg, tc_c)
    trc.run(log=lambda *_: None)
    la = np.asarray(next(iter(__import__("jax").tree.leaves(trb.state["params"]))))
    lc = np.asarray(next(iter(__import__("jax").tree.leaves(trc.state["params"]))))
    np.testing.assert_allclose(la, lc, atol=1e-6)
